"""HLO analyzer vs analytically-known programs (incl. scan trip counts).

Runs in a subprocess-free way: forcing host device count happens in a
separate pytest process via env marker — here we only need 1 device for
unsharded modules, plus a tiny forced-device SPMD case behind a spawn.
"""
import os
import subprocess
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# portable child env (CI checkouts are not /root/repo): keep the host's
# PATH/HOME, and never probe for accelerators in the child — a stripped
# env otherwise stalls minutes in TPU discovery
_CHILD_ENV = {
    "PYTHONPATH": "src",
    "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
    "HOME": os.environ.get("HOME", "/root"),
    "JAX_PLATFORMS": "cpu",
}

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_plain_matmul_flops():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, x, w)
    s = analyze_hlo(txt)
    expect = 2 * 64 * 128 * 32
    assert abs(s.flops - expect) / expect < 0.01, (s.flops, expect)
    # traffic at least operands + result once
    assert s.hbm_bytes >= (64 * 128 + 128 * 32 + 64 * 32) * 4


def test_scan_multiplies_body_flops():
    L = 7
    w = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None

        y, _ = jax.lax.scan(body, x, w)
        return y

    txt = _compile_text(f, w, x)
    s = analyze_hlo(txt)
    expect = L * 2 * 8 * 64 * 64
    assert abs(s.flops - expect) / expect < 0.05, (s.flops, expect)
    assert any(t == L for t in s.trip_counts.values()), s.trip_counts
    # body weight reads happen L times: traffic must exceed L * w_layer bytes
    assert s.hbm_bytes >= L * 64 * 64 * 4


def test_nested_scan():
    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ c2), None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    txt = _compile_text(f, x)
    s = analyze_hlo(txt)
    expect = 5 * 3 * 2 * 32 * 32 * 32
    assert abs(s.flops - expect) / expect < 0.05, (s.flops, expect)


_SPMD_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo

mesh = jax.make_mesh((2, 4), ("data", "model"))
W = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)
X = jax.ShapeDtypeStruct((8, 256), jnp.float32)

def f(w, x):
    def body(c, wl):
        return jnp.tanh(c @ wl), None
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()

with mesh:
    c = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                      NamedSharding(mesh, P("data", None))),
    ).lower(W, X).compile()
s = analyze_hlo(c.as_text())
# per-device flops: 4 layers x 2*4*256*64 (data 2-way, model 4-way)
expect = 4 * 2 * 4 * 256 * 64
assert abs(s.flops - expect) / expect < 0.25, (s.flops, expect)
assert s.total_collective_bytes > 0
print("OK", s.flops, dict(s.collective_bytes))
"""


def test_spmd_per_device_flops_and_collectives():
    r = subprocess.run(
        [sys.executable, "-c", _SPMD_SNIPPET],
        capture_output=True, text=True, timeout=300,
        env=_CHILD_ENV,
        cwd=_REPO_ROOT,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
