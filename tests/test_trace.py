"""Request-lifecycle tracing + expert-routing telemetry
(repro.serving.trace).

Two contracts under test:

* **Determinism** — the wall-clock-free projection of the event stream
  (``deterministic_jsonl``) must be *bit-identical* across replays of
  the same trace, under horizon ∈ {1, 4, 8} × preemption modes ×
  offload budgets — the event-stream extension of the
  ``ServingMetrics.counters()`` replay contract. And the trace level
  must be invisible to the metrics: serving with tracing off produces
  byte-identical counters (and tokens) to serving at full detail.
* **Coverage** — a pressured trace records the whole lifecycle
  (enqueue → admit → prefill chunks → megasteps with compute/replay
  split → page grow → preempt/swap → release) with per-request flow
  events, exports a schema-valid Chrome trace, and the expert-routing
  telemetry joins observed dispatch frequency against PMQ bit widths.

Engine traces reuse the simulation harness (tests/test_serving_sim.py)
and the offloaded-serving fixtures (tests/test_offload.py).
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from test_offload import ECFG, compress_for_serving, make_requests
from test_offload import TINY_MOE as OFFLOAD_MOE
from test_serving_sim import TINY_DENSE, Trace, _random_trace, run_trace

from repro.core.compressed_moe import BucketMeta
from repro.models.registry import get_model
from repro.serving import (
    EngineConfig,
    ExpertRoutingTelemetry,
    MetricsConsumer,
    PagedServingEngine,
    ServingMetrics,
    SpanTracer,
    validate_chrome_trace,
    validate_events,
)
from repro.serving.trace import NULL_TRACER, gini


@pytest.fixture(scope="module")
def dense_model():
    bundle = get_model(TINY_DENSE)
    return TINY_DENSE, bundle.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def compressed_model():
    bundle = get_model(OFFLOAD_MOE)
    params = bundle.init(jax.random.PRNGKey(0))
    return OFFLOAD_MOE, compress_for_serving(OFFLOAD_MOE, params)


# ------------------------------------------------------------ unit: tracer
def test_level_gating():
    """"off" records nothing, "spans" records spans/instants/flows but
    no counters, "full" records everything; bad levels are rejected."""
    def drive(t):
        with t.span("megastep", track="engine", cat="decode"):
            pass
        t.instant("page_grow", track="pool", cat="kv", slot=0)
        t.flow("s", 7, track="queue")
        t.counter("pool", track="engine", page_util=0.5)

    off, spans, full = SpanTracer("off"), SpanTracer("spans"), SpanTracer("full")
    for t in (off, spans, full):
        drive(t)
    assert off.events == [] and not off.enabled and not off.full
    assert [e["ph"] for e in spans.events] == ["X", "i", "s"]
    assert [e["ph"] for e in full.events] == ["X", "i", "s", "C"]
    with pytest.raises(ValueError, match="trace level"):
        SpanTracer("verbose")
    with pytest.raises(ValueError, match="flow phase"):
        full.flow("x", 1, track="queue")
    assert NULL_TRACER.events == []  # the shared default stays inert


def test_deterministic_projection_strips_wall_clock_only():
    t = SpanTracer("full")
    with t.span("decode", track="slot0", cat="decode", rid=3):
        pass
    t.instant("admit", track="slot0", cat="lifecycle", rid=3)
    assert all("ts_us" in e for e in t.events)
    det = t.deterministic_events()
    assert all("ts_us" not in e and "dur_us" not in e for e in det)
    # everything non-wall-clock survives, parseable line by line
    lines = t.deterministic_jsonl().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["args"] == {"rid": 3}
    # reset drops events and keeps the tracer usable
    t.reset()
    assert t.events == []
    t.instant("admit", track="slot0", cat="lifecycle")
    assert t.events[0]["seq"] == 0


def test_event_and_chrome_schema_validation():
    t = SpanTracer("full")
    with t.span("megastep", track="engine", cat="decode", horizon=4):
        t.instant("enqueue", track="queue", cat="lifecycle", rid=1)
    t.flow("s", 1, track="queue")
    t.flow("f", 1, track="slot0")
    assert validate_events(t.events) == 4
    doc = t.chrome_trace(extra={"note": "x"})
    assert validate_chrome_trace(doc) > 4  # metadata events included
    assert doc["otherData"] == {"note": "x"}
    # per-track tid mapping with human-readable thread names
    names = {
        e["args"]["name"] for e in doc["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert names == {"engine", "queue", "slot0"}
    # violations raise
    with pytest.raises(ValueError, match="seq"):
        validate_events([
            {"ph": "i", "name": "a", "cat": "c", "track": "t", "seq": 1,
             "ts_us": 0.0, "args": {}},
            {"ph": "i", "name": "b", "cat": "c", "track": "t", "seq": 1,
             "ts_us": 0.0, "args": {}},
        ])
    with pytest.raises(ValueError, match="flow"):
        validate_events([
            {"ph": "s", "name": "request", "cat": "request", "track": "q",
             "seq": 0, "ts_us": 0.0},
        ])
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"events": []})


def test_lifecycle_feeds_consumers_at_every_level():
    """Metrics book-keep through the lifecycle stream, so the trace
    level cannot change what the counters record."""
    def drive(t):
        t.lifecycle("admit", track="slot0", rid=1, slot=0, step=0,
                    active_before=0, queue_depth=1, resumed=False)
        t.lifecycle("preempt", track="slot0", rid=1, slot=0, step=2,
                    mode="swap", swap_bytes=64)
        t.lifecycle("swap_in", track="slot0", rid=1, slot=0, nbytes=64)
        t.lifecycle("release", track="slot0", rid=1, slot=0, step=5)

    metrics = {}
    for level in ("off", "spans"):
        m = ServingMetrics()
        drive(SpanTracer(level, consumers=(MetricsConsumer(lambda: m),)))
        metrics[level] = m
    direct = ServingMetrics()
    direct.record_admission(1, 0, 0, 0, 1, resumed=False)
    direct.record_preemption(1, 0, 2, "swap", swap_bytes=64)
    direct.record_swap_in(64)
    direct.record_release(1, 0, 5)
    assert metrics["off"].counters() == direct.counters()
    assert metrics["spans"].counters() == direct.counters()


# -------------------------------------------------------- unit: telemetry
def test_gini():
    assert gini([]) == 0.0
    assert gini([0, 0, 0]) == 0.0
    assert gini([5, 5, 5, 5]) == pytest.approx(0.0)
    # all traffic on one of n experts → (n-1)/n
    assert gini([0, 0, 0, 12]) == pytest.approx(0.75)
    assert gini([1, 2, 3, 4]) == pytest.approx(0.25)


def test_telemetry_histogram_drift_and_gauges():
    tel = ExpertRoutingTelemetry(ema_decay=0.5)
    # uniform first step: matches the uniform EMA seed → zero drift
    g = tel.update(np.array([[2, 2], [3, 3]]))
    assert g["routing_drift"] == pytest.approx(0.0)
    assert g["routing_gini"] == pytest.approx(0.0)
    # hard skew: TV distance from the uniform EMA is 0.5 per layer
    g = tel.update(np.array([[4, 0], [0, 4]]))
    assert g["routing_drift"] == pytest.approx(0.5)
    assert tel.hist.tolist() == [[6, 2], [3, 7]]
    assert tel.steps == 2
    # empty / non-2D inputs are ignored
    assert tel.update(np.zeros((2, 0))) is None
    assert tel.steps == 2
    # a layer with zero traffic this step contributes zero drift
    g = tel.update(np.array([[0, 0], [1, 1]]))
    assert np.isfinite(g["routing_drift"])


def test_bit_misallocation_report_joins_freq_and_bits():
    meta = (BucketMeta(bits=1, start=0, count=1),
            BucketMeta(bits=2, start=1, count=2),
            BucketMeta(bits=3, start=3, count=1))
    tel = ExpertRoutingTelemetry()
    assert tel.bit_misallocation_report(meta) is None  # no traffic yet
    # layer 0: slot 0 (1-bit) hottest — a hot_low_bit candidate; slot 3
    # (3-bit) coldest — a cold_high_bit candidate. layer 1: bits follow
    # frequency perfectly (corr > 0), no candidates.
    tel.update(np.array([[10, 2, 2, 1], [1, 4, 4, 10]]))
    rep = tel.bit_misallocation_report(meta)
    assert rep["num_layers"] == 2 and rep["num_slots"] == 4
    assert rep["bits_per_slot"] == [1, 2, 2, 3]
    l0, l1 = rep["layers"]
    assert l0["hot_low_bit"] == [0] and l0["cold_high_bit"] == [3]
    assert l0["freq_bits_corr"] < 0 < l1["freq_bits_corr"]
    assert l1["hot_low_bit"] == [] and l1["cold_high_bit"] == []
    # per-slot join: counts, frequencies and stable ranks all line up
    assert [e["count"] for e in l0["entries"]] == [10, 2, 2, 1]
    assert l0["entries"][0]["freq_rank"] == 0
    assert sorted(e["freq_rank"] for e in l0["entries"]) == [0, 1, 2, 3]
    assert sum(e["freq"] for e in l1["entries"]) == pytest.approx(1.0)
    # uniform bits ⇒ no correlation and no candidates by construction
    flat = (BucketMeta(bits=2, start=0, count=4),)
    rep = tel.bit_misallocation_report(flat)
    assert rep["mean_freq_bits_corr"] is None
    assert rep["layers"][0]["hot_low_bit"] == []


# ------------------------------------------- engine traces: determinism
@pytest.mark.parametrize("horizon,preempt_mode", [
    (1, "swap"), (1, "recompute"), (4, "swap"),
    (4, "recompute"), (8, "swap"), (8, "recompute"),
])
def test_trace_determinism_under_pressure(dense_model, horizon, preempt_mode):
    """Satellite acceptance: identical replays of the same fuzzed trace
    produce bit-identical wall-clock-free event streams, across
    horizons and preemption modes at the tightest admissible pool."""
    cfg, params = dense_model
    base = _random_trace(np.random.default_rng(5))
    trace = dataclasses.replace(
        base, horizon=horizon, pool_blocks=base.min_pool,
        preempt_mode=preempt_mode,
    )
    streams, counters = [], []
    for _ in range(2):
        engine = run_trace(cfg, params, trace, trace_level="full")
        validate_events(engine.tracer.events)
        streams.append(engine.tracer.deterministic_jsonl())
        counters.append(engine.metrics.counters())
    assert streams[0] == streams[1]
    assert counters[0] == counters[1]


@pytest.mark.parametrize("budget,horizon", [(2, 1), (4, 4)])
def test_trace_determinism_offloaded(compressed_model, budget, horizon):
    """Replays with host-offloaded expert buckets (miss replays, EMA
    prefetch, budget grows) still produce bit-identical projections."""
    cfg, params = compressed_model
    ecfg = dataclasses.replace(
        ECFG, resident_experts=budget, decode_horizon=horizon,
        trace_level="full",
    )
    streams, outs = [], []
    for _ in range(2):
        engine = PagedServingEngine(cfg, params, ecfg)
        outs.append(engine.serve(make_requests(cfg, 3, seed=11)))
        validate_events(engine.tracer.events)
        streams.append(engine.tracer.deterministic_jsonl())
    assert outs[0] == outs[1]
    assert streams[0] == streams[1]


def test_tracing_level_invisible_to_counters_and_outputs(dense_model):
    """Acceptance: tracing disabled records zero events yet serves the
    exact same tokens with the exact same deterministic counters."""
    cfg, params = dense_model
    base = _random_trace(np.random.default_rng(21))
    trace = dataclasses.replace(
        base, pool_blocks=base.min_pool, preempt_mode="swap", horizon=4
    )
    e_off = run_trace(cfg, params, trace, trace_level="off")
    e_full = run_trace(cfg, params, trace, trace_level="full")
    assert e_off.tracer.events == []
    assert len(e_full.tracer.events) > 0
    assert dict(e_off.results) == dict(e_full.results)
    assert e_off.metrics.counters() == e_full.metrics.counters()


# --------------------------------------------- engine traces: coverage
def test_trace_covers_full_lifecycle_with_preemption(dense_model):
    """A deterministically preempting trace records every lifecycle
    event type, stitches each request's journey with flow events, and
    exports a schema-valid Chrome trace with per-track metadata."""
    cfg, params = dense_model
    # pool of 4 pages admits both 2-token-prompt requests (2 pages each,
    # horizon-ahead), then the first growth demand finds zero free pages
    # and must preempt the youngest — guaranteed pressure
    trace = Trace((2, 2), (10, 10), (0, 0), 4, "swap", max_slots=2,
                  horizon=4)
    engine = run_trace(cfg, params, trace, trace_level="full")
    ev = engine.tracer.events
    validate_events(ev)
    names = {e["name"] for e in ev}
    assert {
        "enqueue", "admit", "prefill_chunk", "first_token", "compute",
        "megastep", "decode", "page_grow", "preempt", "kv_swap_out",
        "swap_in", "kv_swap_in", "release", "request", "pool",
    } <= names
    assert engine.metrics.counters()["preemptions"], "trace must preempt"
    # the preempted request was re-admitted as resumed
    assert any(
        e["name"] == "admit" and e["args"]["resumed"] for e in ev
    )
    # flows: every request starts on the queue ("s"), hops ≥ once ("t"),
    # finishes exactly once ("f")
    for rid in (0, 1):
        phases = [e["ph"] for e in ev if e.get("id") == rid]
        assert phases.count("s") == 1
        assert phases.count("f") == 1
        assert "t" in phases
    # spans carry their extents; instants don't
    for e in ev:
        assert (e["ph"] == "X") == ("dur_us" in e)
    doc = engine.tracer.chrome_trace()
    validate_chrome_trace(doc)
    tracks = {
        e["args"]["name"] for e in doc["traceEvents"]
        if e["ph"] == "M" and e["name"] == "thread_name"
    }
    assert {"engine", "queue", "pool", "slot0", "slot1"} <= tracks


def test_offloaded_trace_has_upload_spans_and_replays(compressed_model):
    """Starving the expert budget must surface miss uploads (with kind
    and byte args) and replay spans in the trace."""
    cfg, params = compressed_model
    ecfg = dataclasses.replace(
        ECFG, resident_experts=2, decode_horizon=2, trace_level="full"
    )
    engine = PagedServingEngine(cfg, params, ecfg)
    engine.serve(make_requests(cfg, 3, seed=11))
    ev = engine.tracer.events
    ups = [e for e in ev if e["name"] == "expert_upload"]
    assert ups, "budget 2 of 4 slots must miss at least once"
    assert all(e["args"]["kind"] in ("miss", "prefetch") for e in ups)
    assert any(e["args"]["kind"] == "miss" for e in ups)
    assert all(e["args"]["bytes"] > 0 for e in ups)
    assert any(e["name"] == "replay" for e in ev), (
        "a miss must replay the program"
    )
    # full level records the routing gauges alongside
    assert any(e["name"] == "routing" and e["ph"] == "C" for e in ev)


def test_routing_report_from_served_engine(compressed_model):
    """Acceptance: the bit-misallocation report joins per-(layer, slot)
    observed dispatch frequency with the PMQ bit assignment."""
    cfg, params = compressed_model
    engine = PagedServingEngine(
        cfg, params, dataclasses.replace(ECFG, trace_level="full")
    )
    engine.serve(make_requests(cfg, 2, seed=3))
    rep = engine.routing_report()
    assert rep is not None
    assert rep["num_slots"] == 4
    assert rep["bits_per_slot"] == [1, 2, 2, 3]  # BITS buckets, permuted
    assert rep["steps"] > 0
    for layer in rep["layers"]:
        assert layer["total_dispatch"] > 0
        assert len(layer["entries"]) == 4
        assert sum(e["freq"] for e in layer["entries"]) == pytest.approx(1.0)
        assert sorted(e["freq_rank"] for e in layer["entries"]) == [0, 1, 2, 3]
        for e in layer["entries"]:
            assert e["bits"] == rep["bits_per_slot"][e["slot"]]
    # the report rides inside the Chrome artifact for offline reading
    doc = engine.tracer.chrome_trace(extra={"routing_report": rep})
    validate_chrome_trace(doc)
    assert doc["otherData"]["routing_report"]["num_slots"] == 4


def test_engine_without_tracing_has_no_telemetry(dense_model):
    """Dense models (no PMQ slot counts) and untraced engines keep the
    telemetry off — routing_report degrades to None, never crashes."""
    cfg, params = dense_model
    trace = Trace((4,), (4,), (0,), 4, "swap", max_slots=1)
    engine = run_trace(cfg, params, trace)  # default level: off
    assert engine.routing is None
    assert engine.routing_report() is None
