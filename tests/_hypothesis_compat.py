"""Soft dependency on ``hypothesis`` (pinned in requirements-dev.txt).

Property tests decorate with the real ``@given``/``@settings`` when
hypothesis is installed; otherwise they collect as *skipped* instead of
failing the whole module at import time — a missing dev extra must never
take the plain unit tests down with it.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies`` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f
