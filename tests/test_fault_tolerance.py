"""Direct unit tests for the fault-tolerance runtime primitives.

The serving watchdog (PagedServingEngine) reuses HeartbeatTable with an
injected clock; these tests drive every primitive with fake clocks and
injected callbacks so expiry, straggler flagging, and restart policy are
exercised deterministically — no sleeps, no wall time.
"""
import pytest

from repro.runtime.fault_tolerance import (
    FailurePolicy,
    HeartbeatTable,
    ResilientLoop,
    StragglerMonitor,
)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ------------------------------------------------------- HeartbeatTable
def test_heartbeat_expiry_via_injected_clock():
    clk = FakeClock()
    hb = HeartbeatTable([0, 1, 2], timeout=10.0, clock=clk)
    assert hb.failed() == []
    assert sorted(hb.alive()) == [0, 1, 2]
    # host 1 keeps beating; 0 and 2 go silent
    clk.advance(8.0)
    hb.beat(1)
    clk.advance(8.0)  # 0/2 last seen 16s ago; 1 seen 8s ago
    assert sorted(hb.failed()) == [0, 2]
    assert hb.alive() == [1]
    # a late beat resurrects the host — deadline detectors hold no grudge
    hb.beat(0)
    assert hb.failed() == [2]
    assert sorted(hb.alive()) == [0, 1]


def test_heartbeat_explicit_now_overrides_clock():
    clk = FakeClock(100.0)
    hb = HeartbeatTable([7], timeout=5.0, clock=clk)
    # explicit `now` wins over the injected clock in both beat and failed
    hb.beat(7, now=200.0)
    assert hb.failed(now=204.0) == []
    assert hb.failed(now=206.0) == [7]
    # and the injected clock (stuck at 100 < 200) sees the host alive
    assert hb.failed() == []


def test_heartbeat_boundary_is_strict():
    clk = FakeClock()
    hb = HeartbeatTable([0], timeout=10.0, clock=clk)
    clk.advance(10.0)
    assert hb.failed() == []  # exactly at the deadline: still alive
    clk.advance(1e-9)
    assert hb.failed() == [0]


# ----------------------------------------------------- StragglerMonitor
def test_straggler_flags_slow_host():
    mon = StragglerMonitor(window=8, threshold=1.5)
    for _ in range(6):
        for h in (0, 1, 2):
            mon.record(h, 1.0)
        mon.record(3, 2.0)  # consistently 2x the fleet median
    assert mon.stragglers() == [3]


def test_straggler_needs_history_and_peers():
    mon = StragglerMonitor(window=8, threshold=1.5)
    # fewer than 4 samples per host: no verdicts
    for h in (0, 1):
        for _ in range(3):
            mon.record(h, 1.0)
    assert mon.stragglers() == []
    # one host alone can never be a straggler relative to itself
    solo = StragglerMonitor()
    for _ in range(8):
        solo.record(0, 9.0)
    assert solo.stragglers() == []


def test_straggler_recovers_as_window_slides():
    mon = StragglerMonitor(window=4, threshold=1.5)
    for _ in range(4):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        mon.record(2, 4.0)
    assert mon.stragglers() == [2]
    # the slow host speeds up; the rolling window forgets the bad epoch
    for _ in range(4):
        mon.record(0, 1.0)
        mon.record(1, 1.0)
        mon.record(2, 1.0)
    assert mon.stragglers() == []


# ------------------------------------------------------- ResilientLoop
def test_resilient_loop_restores_and_completes():
    calls = {"restore": 0}
    boom = {5: True}  # step 5 fails exactly once

    def step(i):
        if boom.pop(i, False):
            raise RuntimeError("injected step fault")
        return {"step": i}

    loop = ResilientLoop(FailurePolicy(
        max_restarts=3, restore_fn=lambda: calls.__setitem__(
            "restore", calls["restore"] + 1),
    ))
    out = loop.run(step, start=0, steps=10)
    assert out == {"step": 9}
    assert loop.restarts == 1
    assert calls["restore"] == 1
    assert [e for e in loop.events if "error" in e] == [
        {"step": 5, "error": repr(RuntimeError("injected step fault"))}
    ]


def test_resilient_loop_shrinks_then_gives_up():
    actions = []
    loop = ResilientLoop(FailurePolicy(
        max_restarts=2, shrink_after=2,
        restore_fn=lambda: actions.append("restore"),
        shrink_fn=lambda: actions.append("shrink"),
    ))

    def always_fails(i):
        raise ValueError("permanent fault")

    with pytest.raises(RuntimeError, match="exceeded max_restarts=2"):
        loop.run(always_fails, start=0, steps=4)
    # restart 1: restore only; restart 2: shrink then restore; restart 3
    # would exceed max_restarts → raises before any action
    assert actions == ["restore", "shrink", "restore"]
    assert loop.restarts == 3
    shrink_events = [e for e in loop.events if e.get("action") == "shrink"]
    assert len(shrink_events) == 1
