"""Randomized serving-simulation harness for dynamic page growth +
preemption.

The allocator/scheduler state machine is pure host code — exactly where
silent corruption hides — so this module fuzzes it: random arrival
traces (prompt lengths, max_new, submit steps, pool sizes, preemption
mode) drive :meth:`PagedServingEngine.step` directly, and after *every*
step the harness asserts the structural invariants:

* no KV page is owned by two live slots (``check_consistency``),
* free-count conservation: free + owned == pool,
* every active slot's pages cover its logical length,
* preempted/waiting requests hold no slot and no pages,

and after the trace drains:

* every admitted request finished with exactly ``max_new`` tokens,
* the pool and the slot list are fully free again,
* greedy outputs are **bit-identical** to ``dense_greedy_reference``
  regardless of pool size or preemption schedule — for any pool that
  admits the largest single request, compression of the page pool must
  never change what a request decodes.

Property tests run under ``hypothesis`` when installed (CI installs
requirements-dev.txt; see tests/conftest.py for the example caps);
seeded trace tests cover the same driver unconditionally.
"""
import dataclasses

import jax
import numpy as np
import pytest

from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs.base import ModelConfig
from repro.models.registry import get_model
from repro.serving import (
    DeadlineExceeded,
    EngineConfig,
    ExpertUploadFailed,
    FaultPlan,
    FaultSpec,
    InvalidRequest,
    LivelockDetected,
    PagedServingEngine,
    PoisonedRequest,
    Request,
    RequestCancelled,
    ServingFault,
    VALID_POLICIES,
    WatchdogTimeout,
)
from repro.serving.engine import dense_greedy_reference

TINY_DENSE = ModelConfig(
    name="tiny-sim-dense",
    family="dense",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab_size=64,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=8,
    attn_kv_chunk=8,
)

TINY_MOE = ModelConfig(
    name="tiny-sim-moe",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    d_ff_expert=64,
    vocab_size=128,
    num_experts=4,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=16,
    attn_kv_chunk=16,
)

BLOCK = 4
MAX_TICKS = 10_000  # liveness bound: a trace that won't drain is a bug


@pytest.fixture(autouse=True)
def _bound_live_executables():
    """Per-test jax cache clear, tighter than conftest's per-module one.

    The policy-invariance sweeps serve each trace once per policy, so
    this module now compiles ~3x the engines it used to; keeping every
    executable alive across the whole module segfaults the XLA CPU
    compiler on small runners (same failure mode the per-module clear
    was added for). Cross-test shape reuse is minimal here — traces are
    test-unique and the dense references are memoized by output in
    ``_REF_CACHE`` — so the clear costs little."""
    yield
    jax.clear_caches()


@pytest.fixture(scope="module")
def dense_model():
    bundle = get_model(TINY_DENSE)
    return TINY_DENSE, bundle.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_model():
    bundle = get_model(TINY_MOE)
    return TINY_MOE, bundle.init(jax.random.PRNGKey(0))


# ------------------------------------------------------------ trace spec
@dataclasses.dataclass(frozen=True)
class Trace:
    """One simulated workload: request shapes + arrival steps + pool.

    ``template_len > 0`` switches prompts to the **shared-template**
    shape (system-prompt workloads): request ``i`` is one of
    ``n_templates`` fixed ``template_len``-token prefixes followed by
    ``prompt_lens[i]`` random suffix tokens — the workload the
    shared-prefix KV cache (``prefix_cache=True``) is built for, and
    the adversarial one for it when the cache is off. Suffixes of
    length 0 repeat a template verbatim (full-prompt hits).

    ``tenants``/``priorities`` label request ``i`` for the multi-tenant
    scheduler policies (empty → everyone is ``"default"`` at priority
    0, the single-tenant traces above); ``policy``/``tenant_weights``/
    ``ttft_budget_steps`` pass straight through to ``EngineConfig``."""

    prompt_lens: tuple
    max_news: tuple
    submit_steps: tuple
    pool_blocks: int
    preempt_mode: str
    max_slots: int = 3
    horizon: int = 1  # fused decode megastep length (H)
    template_len: int = 0  # shared-prefix template tokens (0 = disjoint)
    n_templates: int = 1
    prefix_cache: bool = False
    tenants: tuple = ()  # per-request tenant label (() = all "default")
    priorities: tuple = ()  # per-request priority class (() = all 0)
    policy: str = "fcfs"  # admission-order policy (fcfs/priority/fair)
    tenant_weights: tuple = ()  # (("tenant", weight), ...) for "fair"
    ttft_budget_steps: int = -1  # SLO shed budget in steps (-1 = off)

    @property
    def full_lens(self) -> tuple:
        return tuple(self.template_len + p for p in self.prompt_lens)

    def requests(self, vocab: int):
        rng = np.random.default_rng(1234)  # prompts derive from the shape
        templates = [
            rng.integers(0, vocab, size=self.template_len).astype(np.int32)
            for _ in range(self.n_templates)
        ]
        reqs = []
        for i, (p, m) in enumerate(zip(self.prompt_lens, self.max_news)):
            suffix = rng.integers(0, vocab, size=p).astype(np.int32)
            prompt = (
                np.concatenate([templates[i % self.n_templates], suffix])
                if self.template_len else suffix
            )
            reqs.append(Request(
                rid=i, prompt=prompt, max_new=m,
                tenant=self.tenants[i] if self.tenants else "default",
                priority=self.priorities[i] if self.priorities else 0,
            ))
        return reqs

    @property
    def min_pool(self) -> int:
        """Smallest pool that admits the largest single request."""
        return max(
            -(-(p + m) // BLOCK)
            for p, m in zip(self.full_lens, self.max_news)
        )

    @property
    def demand(self) -> int:
        return sum(
            -(-(p + m) // BLOCK)
            for p, m in zip(self.full_lens, self.max_news)
        )


def check_invariants(engine: PagedServingEngine) -> None:
    """Structural invariants, asserted after every engine step."""
    engine.cache.check_consistency()
    sched, cache = engine.scheduler, engine.cache
    for slot, req in sched.active.items():
        assert req.slot == slot, "active map out of sync with request"
        blocks = cache.slot_blocks[slot]
        assert len(blocks) * cache.block_size >= req.pos, (
            f"slot {slot}: {len(blocks)} pages cannot cover pos={req.pos}"
        )
        assert req.swapped is None, "active request still holds swapped KV"
    for req in sched.waiting:
        assert req.slot == -1, "queued request holds a slot"
        if req.swapped is not None:
            assert req.swapped.n_tokens == req.pos


def make_engine(cfg, params, trace: Trace, faults=None, **ecfg_kw):
    """Build the engine a :class:`Trace` describes (shared by
    :func:`run_trace` and the fault-plane drivers below)."""
    mb = -(-(max(p + m for p, m in zip(trace.full_lens, trace.max_news)))
           // BLOCK)
    return PagedServingEngine(
        cfg, params,
        EngineConfig(
            max_slots=trace.max_slots,
            block_size=BLOCK,
            num_blocks=trace.pool_blocks,
            max_blocks_per_slot=mb,
            prefill_chunk=BLOCK,
            preempt_mode=trace.preempt_mode,
            decode_horizon=trace.horizon,
            prefix_cache=trace.prefix_cache,
            policy=trace.policy,
            tenant_weights=trace.tenant_weights or None,
            ttft_budget_steps=(
                trace.ttft_budget_steps if trace.ttft_budget_steps >= 0
                else None
            ),
            **ecfg_kw,
        ),
        faults=faults,
    )


def assert_drained_clean(engine, trace: Trace) -> None:
    """Post-drain pool hygiene: everything finished (or terminated with
    a typed error); every page is either free or held *only* by the
    prefix cache (ready for the next batch), and a cache teardown
    returns the pool to fully free."""
    assert not engine.scheduler.active and not engine.scheduler.waiting
    cache = engine.cache
    held = cache.prefix.pages_held if cache.prefix is not None else frozenset()
    assert cache.allocator.allocated == held, (
        "drained pool holds pages unreachable from the prefix cache"
    )
    assert cache.allocator.num_free + len(held) == trace.pool_blocks
    assert sorted(cache.free_slots) == list(range(trace.max_slots))
    assert cache.slot_blocks == {}
    cache.check_consistency()
    cache.clear_prefix_cache()
    assert cache.allocator.num_free == trace.pool_blocks


def run_trace(cfg, params, trace: Trace, faults=None, **ecfg_kw):
    """Drive the engine step-by-step, interleaving arrivals, checking
    invariants throughout. Returns the finished engine. ``ecfg_kw``
    passes extra :class:`EngineConfig` fields through (e.g.
    ``trace_level`` for the span-tracer determinism tests);
    ``faults`` attaches a :class:`FaultPlan` (the fault-plane fuzz)."""
    engine = make_engine(cfg, params, trace, faults=faults, **ecfg_kw)
    pending = sorted(
        zip(trace.submit_steps, trace.requests(cfg.vocab_size)),
        key=lambda t: t[0],
    )
    tick = 0
    while pending or engine.scheduler.has_work():
        assert tick < MAX_TICKS, "trace failed to drain (livelock?)"
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        if engine.scheduler.has_work():
            engine.step()
            check_invariants(engine)
        tick += 1
    assert_drained_clean(engine, trace)
    return engine


_REF_CACHE: dict = {}


def reference_tokens(cfg, params, prompt: np.ndarray, max_new: int):
    """Memoized dense greedy reference (shared across pool sizes/modes —
    the whole point is that outputs must not depend on them)."""
    key = (cfg.name, cfg.moe_capacity_factor, prompt.tobytes(), max_new)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = dense_greedy_reference(cfg, params, prompt, max_new)[0]
    return _REF_CACHE[key]


def assert_outputs_match_reference(cfg, params, engine, trace):
    # the reference runs at the engine's drop-free expert capacity so the
    # comparison isolates paging/preemption from MoE token dropping.
    # Shed requests (SLO budget exceeded before first admission) are the
    # one sanctioned deviation: they must emit *nothing* — a shed that
    # leaks tokens would be a silent partial result.
    mcfg = engine.model_cfg
    shed_rids = {rec["rid"] for rec in engine.metrics.sheds}
    for req in trace.requests(cfg.vocab_size):
        got = engine.results[req.rid]
        if req.rid in shed_rids:
            assert got == [], f"rid={req.rid} was shed but emitted tokens"
            continue
        ref = reference_tokens(mcfg, params, req.prompt, req.max_new)
        assert got == ref, (
            f"rid={req.rid} pool={trace.pool_blocks} mode={trace.preempt_mode}: "
            f"{got} != dense reference {ref}"
        )


# --------------------------------------------------- seeded simulations
def _random_trace(rng: np.random.Generator) -> Trace:
    n = int(rng.integers(2, 7))
    prompt_lens = tuple(int(x) for x in rng.integers(1, 9, n))
    max_news = tuple(int(x) for x in rng.integers(1, 11, n))
    submit_steps = tuple(sorted(int(x) for x in rng.integers(0, 6, n)))
    t = Trace(prompt_lens, max_news, submit_steps, 0,
              str(rng.choice(["swap", "recompute"])),
              horizon=int(rng.choice([1, 2, 4, 8])),
              template_len=int(rng.choice([0, 0, 4, 8])),
              n_templates=int(rng.integers(1, 3)),
              prefix_cache=bool(rng.integers(0, 2)))
    lo, hi = t.min_pool, max(t.min_pool + 1, t.demand)
    pool = int(rng.integers(lo, hi + 1))
    return dataclasses.replace(t, pool_blocks=pool)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_trace_seeded(dense_model, seed):
    """Always-on randomized simulation (no hypothesis needed): random
    arrivals + tight random pools + random decode horizons keep every
    invariant and reproduce the dense reference bit-for-bit."""
    cfg, params = dense_model
    trace = _random_trace(np.random.default_rng(seed))
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)


@pytest.mark.parametrize("horizon", [1, 2, 4, 8])
def test_horizon_equivalence_under_pressure(dense_model, horizon):
    """Acceptance: for H ∈ {1, 2, 4, 8} over the same tight-pool trace
    (preemptions included), greedy outputs are bit-identical to the
    dense reference — the fused megastep must be invisible to what a
    request decodes."""
    cfg, params = dense_model
    base = _random_trace(np.random.default_rng(13))
    trace = dataclasses.replace(
        base, horizon=horizon, pool_blocks=base.min_pool, preempt_mode="swap"
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    m = engine.metrics.summary()
    # the jitted-dispatch amortization is real, not just asserted: per
    # logical decode step the engine paid ≤ 1/H dispatches (+ tail slack)
    assert m["dispatches_per_step"] <= 1.0 / horizon + 0.35
    if horizon == 1:
        assert m["dispatches_per_step"] == 1.0


def test_eos_mid_horizon_in_simulation(dense_model):
    """A request whose EOS lands mid-megastep emits no extra tokens,
    frees its slot at the right logical step, and the remaining traffic
    still matches the reference."""
    cfg, params = dense_model
    # find a prompt whose greedy reference emits a *first-occurrence*
    # token at a mid-horizon decode step (tiny models often oscillate
    # between two tokens, so search a few seeds deterministically)
    rng = np.random.default_rng(99)
    target = None
    for _ in range(40):
        prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
        ref = reference_tokens(cfg, params, prompt, 8)
        for cut in (4, 3, 2):  # EOS at decode step cut-1 of the megastep
            if ref[cut] not in ref[:cut]:
                target = (prompt, ref, cut)
                break
        if target:
            break
    assert target is not None, "no mid-horizon EOS candidate found"
    prompt, ref0, cut = target
    eos = ref0[cut]
    other = np.random.default_rng(1234).integers(
        0, cfg.vocab_size, size=3
    ).astype(np.int32)
    pool = -(-(5 + 8) // BLOCK) + -(-(3 + 8) // BLOCK)
    mb = -(-(8 + 8) // BLOCK)
    engine = PagedServingEngine(
        cfg, params,
        EngineConfig(max_slots=3, block_size=BLOCK, num_blocks=pool,
                     max_blocks_per_slot=mb, prefill_chunk=BLOCK,
                     decode_horizon=4),
    )
    out = engine.serve([
        Request(rid=0, prompt=prompt, max_new=8, eos_id=eos),
        Request(rid=1, prompt=other, max_new=8),
    ])
    assert out[0] == ref0[: cut + 1]  # truncated at (and incl.) the EOS
    assert out[1] == reference_tokens(cfg, params, other, 8)
    release = {r["rid"]: r["step"] for r in engine.metrics.slot_releases}
    # tokens 1..cut decode at logical steps 0..cut-1
    assert release[0] == cut - 1
    # every page returned the moment the trace drained
    assert engine.cache.allocator.num_free == pool


def test_minimal_pool_single_request_alone(dense_model):
    """Pool == exactly the largest request's pages: it must run start to
    finish with zero preemptions (self-preemption would livelock)."""
    cfg, params = dense_model
    trace = Trace((6,), (10,), (0,), 0, "swap")
    trace = dataclasses.replace(trace, pool_blocks=trace.min_pool)
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    assert engine.metrics.summary()["preemptions"] == 0


# --------------------------------------------------- hypothesis fuzzing
if HAS_HYPOTHESIS:
    @st.composite
    def traces(draw):
        n = draw(st.integers(min_value=2, max_value=5))
        template_len = draw(st.sampled_from([0, 4, 8]))
        # suffixes may be empty under a template (verbatim repeats →
        # full-prompt cache hits); standalone prompts must be non-empty
        min_suffix = 0 if template_len else 1
        prompt_lens = tuple(
            draw(st.lists(st.integers(min_suffix, 8), min_size=n, max_size=n))
        )
        max_news = tuple(
            draw(st.lists(st.integers(1, 8), min_size=n, max_size=n))
        )
        submit_steps = tuple(
            sorted(draw(st.lists(st.integers(0, 5), min_size=n, max_size=n)))
        )
        t = Trace(prompt_lens, max_news, submit_steps, 0,
                  draw(st.sampled_from(["swap", "recompute"])),
                  horizon=draw(st.sampled_from([1, 2, 4, 8])),
                  template_len=template_len,
                  n_templates=draw(st.integers(1, 2)) if template_len else 1,
                  prefix_cache=draw(st.booleans()))
        pool = draw(
            st.integers(t.min_pool, max(t.min_pool, t.demand))
        )
        return dataclasses.replace(t, pool_blocks=pool)
else:  # decoration-time stand-in; the test below collects as skipped
    def traces():
        return None


@given(trace=traces())
@settings()  # example counts/deadline come from the conftest profiles
def test_property_any_pool_any_schedule(dense_model, trace):
    """Hypothesis: for ANY arrival trace (shared-template prompts
    included), ANY pool size that admits the largest single request,
    ANY decode horizon, and the prefix cache on or off, the engine
    drains with all invariants intact and emits bit-identical greedy
    outputs."""
    cfg, params = dense_model
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)


# ----------------------------------------------- shared-prefix KV reuse
@pytest.mark.parametrize("horizon", [1, 4, 8])
@pytest.mark.parametrize("preempt_mode", ["swap", "recompute"])
def test_shared_prefix_cache_invisible_under_pressure(
    dense_model, horizon, preempt_mode
):
    """Acceptance (tentpole a): a shared-template trace through a
    pressured pool decodes **bit-identically with the prefix cache on
    and off** across horizons and preemption modes — KV reuse, COW page
    sharing and cache eviction must be invisible to what any request
    decodes — while the cache-on run actually reuses pages (hits >
    0, prefill tokens saved) and keeps every refcount invariant
    (checked after each step by ``run_trace``)."""
    cfg, params = dense_model
    rng = np.random.default_rng(21)
    n = 8
    base = Trace(
        prompt_lens=tuple(int(x) for x in rng.integers(0, 5, n)),
        max_news=tuple(int(x) for x in rng.integers(3, 9, n)),
        submit_steps=tuple(sorted(int(x) for x in rng.integers(0, 4, n))),
        pool_blocks=0,
        preempt_mode=preempt_mode,
        max_slots=4,
        horizon=horizon,
        template_len=8,
        n_templates=2,
    )
    pool = max(base.min_pool, (2 * base.demand) // 3)
    base = dataclasses.replace(base, pool_blocks=pool)
    eng_off = run_trace(cfg, params, base)
    eng_on = run_trace(
        cfg, params, dataclasses.replace(base, prefix_cache=True)
    )
    assert eng_on.results == eng_off.results
    m = eng_on.metrics.summary()
    assert m["prefix_hits"] >= 1 and m["prefix_tokens_saved"] > 0
    assert m["prefix_hits"] + m["prefix_misses"] >= n
    assert_outputs_match_reference(cfg, params, eng_on, base)


def test_shared_prefix_full_hits_skip_prefill(dense_model):
    """Verbatim template repeats (suffix length 0) admit through
    *full-prompt* hits: the repeats dispatch zero prefill programs —
    their first token comes from the cached registration-time logits —
    and still decode bit-identically to the dense reference."""
    cfg, params = dense_model
    trace = Trace(
        prompt_lens=(0,) * 4, max_news=(4,) * 4,
        submit_steps=(0, 1, 2, 3), pool_blocks=12,
        preempt_mode="swap", horizon=4, template_len=6, n_templates=1,
        prefix_cache=True,
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    m = engine.metrics.summary()
    assert m["prefix_full_hits"] == 3  # every admission after the first
    # prefill ran only for the first request: ceil(6 / BLOCK) chunks
    assert m["prefill_dispatches"] == -(-6 // BLOCK)


@pytest.mark.parametrize("kv_bits", [None, 8])
def test_quantized_template_trace_matches_isolated_oracle(
    moe_model, kv_bits
):
    """Acceptance (tentpole b): an int8-KV engine under template
    sharing + pool pressure emits exactly the tokens each request gets
    when served **alone** in a fresh single-slot engine of the same
    ``kv_bits`` (different page geometry) — batch-composition
    independence, the repo's core invariant, carried over to quantized
    pools. ``kv_bits=None`` pins the fp leg of the same trace to the
    dense oracle."""
    from repro.serving import quantized_greedy_reference

    cfg, params = moe_model
    rng = np.random.default_rng(5)
    n = 6
    base = Trace(
        prompt_lens=tuple(int(x) for x in rng.integers(1, 5, n)),
        max_news=tuple(int(x) for x in rng.integers(3, 8, n)),
        submit_steps=(0,) * n,
        pool_blocks=0,
        preempt_mode="swap",
        max_slots=4,
        horizon=4,
        template_len=4,
        n_templates=2,
        prefix_cache=True,
    )
    base = dataclasses.replace(
        base, pool_blocks=max(base.min_pool, (2 * base.demand) // 3)
    )
    engine = run_trace(cfg, params, base, kv_bits=kv_bits)
    if kv_bits is None:
        assert_outputs_match_reference(cfg, params, engine, base)
        return
    for req in base.requests(cfg.vocab_size):
        ref = quantized_greedy_reference(
            cfg, params, req.prompt, req.max_new, kv_bits=kv_bits,
            block_size=8,  # page geometry must not enter the math
        )
        assert engine.results[req.rid] == ref, (
            f"rid={req.rid}: quantized engine diverged from its "
            f"isolated-oracle tokens"
        )


# ------------------------------------------------- flagship: 50% pool
@pytest.mark.parametrize("preempt_mode", ["swap", "recompute"])
def test_half_pool_mixed_trace_preempts_and_matches(moe_model, preempt_mode):
    """Acceptance: a 12-request mixed-length trace through a pool sized
    at 50% of total demand completes with ≥1 preemption, and every
    request's greedy output is bit-identical to the dense reference —
    on the MoE path (drop-free capacity), the paper's serving setting."""
    cfg, params = moe_model
    rng = np.random.default_rng(7)
    prompt_lens = tuple(int(x) for x in rng.integers(2, 7, 12))
    max_news = tuple(int(x) for x in rng.integers(6, 13, 12))
    trace = Trace(
        prompt_lens, max_news, (0,) * 12, 0, preempt_mode, max_slots=8
    )
    pool = max(trace.demand // 2, trace.min_pool)
    trace = dataclasses.replace(trace, pool_blocks=pool)
    assert trace.pool_blocks <= trace.demand // 2  # genuinely 50% pressure
    engine = run_trace(cfg, params, trace)
    m = engine.metrics.summary()
    assert m["preemptions"] >= 1, "50% pool must force at least one preemption"
    if preempt_mode == "swap":
        assert m["swap_bytes"] > 0
        assert m["swap_in_bytes"] == m["swap_out_bytes"]
    assert m["page_util_p95"] > 0.8  # growth actually packs the pool
    assert_outputs_match_reference(cfg, params, engine, trace)


# ---------------------------------------------------- deterministic replay
def test_deterministic_replay_identical_outputs_and_counters(dense_model):
    """Identical trace + seed ⇒ identical per-request outputs and
    identical wall-clock-free metrics counters across two engine runs
    (guards nondeterministic victim selection / iteration order)."""
    cfg, params = dense_model
    trace = _random_trace(np.random.default_rng(42))
    # make sure the replayed schedule exercises the interesting machinery
    trace = dataclasses.replace(
        trace, pool_blocks=trace.min_pool, preempt_mode="swap", horizon=4
    )
    runs = []
    for _ in range(2):
        engine = run_trace(cfg, params, trace)
        runs.append((dict(engine.results), engine.metrics.counters()))
    (out_a, ctr_a), (out_b, ctr_b) = runs
    assert out_a == out_b
    assert ctr_a == ctr_b


# ------------------------------------------------ multi-tenant scheduling
FAIR_WEIGHTS = (("batch", 1.0), ("chat", 2.0), ("interactive", 4.0))


def _tenant_mix_trace(rng: np.random.Generator) -> Trace:
    """The three-tenant production mix: a long-document **batch** tenant
    (big prompts + long decodes, all submitted at step 0, priority 0), a
    bursty **chat** tenant (medium requests arriving in one burst,
    priority 1), and a latency-floor **interactive** tenant (tiny
    requests trickling in, priority 2)."""
    batch_n = int(rng.integers(2, 4))
    chat_n = int(rng.integers(3, 6))
    inter_n = int(rng.integers(2, 5))
    lens, news, submits, tenants, prios = [], [], [], [], []
    for _ in range(batch_n):
        lens.append(int(rng.integers(8, 13)))
        news.append(int(rng.integers(6, 11)))
        submits.append(0)
        tenants.append("batch")
        prios.append(0)
    burst_at = int(rng.integers(0, 3))
    for _ in range(chat_n):
        lens.append(int(rng.integers(2, 6)))
        news.append(int(rng.integers(2, 7)))
        submits.append(burst_at)
        tenants.append("chat")
        prios.append(1)
    for _ in range(inter_n):
        lens.append(int(rng.integers(1, 4)))
        news.append(int(rng.integers(1, 5)))
        submits.append(int(rng.integers(1, 6)))
        tenants.append("interactive")
        prios.append(2)
    t = Trace(
        tuple(lens), tuple(news), tuple(submits), 0,
        str(rng.choice(["swap", "recompute"])),
        max_slots=4,
        horizon=int(rng.choice([1, 2, 4])),
        tenants=tuple(tenants), priorities=tuple(prios),
        tenant_weights=FAIR_WEIGHTS,
    )
    pool = int(rng.integers(t.min_pool, max(t.min_pool + 1,
                                            (3 * t.demand) // 4)))
    return dataclasses.replace(t, pool_blocks=pool)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_policy_invariance_tenant_mix(dense_model, seed):
    """Acceptance: the same tenant-mix trace served under fcfs,
    priority, and fair produces **bit-identical per-request outputs**
    (and each matches the dense reference) — scheduling policy may
    reorder *when* a request runs, never *what* it decodes. Invariants
    are checked after every step by ``run_trace``."""
    cfg, params = dense_model
    base = _tenant_mix_trace(np.random.default_rng(100 + seed))
    runs = {}
    for policy in VALID_POLICIES:
        trace = dataclasses.replace(base, policy=policy)
        engine = run_trace(cfg, params, trace)
        assert_outputs_match_reference(cfg, params, engine, trace)
        runs[policy] = dict(engine.results)
    assert runs["priority"] == runs["fcfs"]
    assert runs["fair"] == runs["fcfs"]


@pytest.mark.parametrize("preempt_mode", ["swap", "recompute"])
@pytest.mark.parametrize("horizon", [1, 4])
def test_policy_invariance_across_horizon_and_preempt(
    dense_model, horizon, preempt_mode
):
    """The policy-invariance sweep crossed with decode horizon and
    preemption mode on one pressured tenant mix: outputs identical
    across all three policies in every cell."""
    cfg, params = dense_model
    base = _tenant_mix_trace(np.random.default_rng(7))
    base = dataclasses.replace(
        base, horizon=horizon, preempt_mode=preempt_mode,
        pool_blocks=max(base.min_pool, (2 * base.demand) // 3),
    )
    outs = []
    for policy in VALID_POLICIES:
        trace = dataclasses.replace(base, policy=policy)
        engine = run_trace(cfg, params, trace)
        assert_outputs_match_reference(cfg, params, engine, trace)
        outs.append(dict(engine.results))
    assert outs[0] == outs[1] == outs[2]


def test_slo_shed_under_saturation(dense_model):
    """A single-slot engine pinned by one long batch request must shed
    the interactive requests stuck behind it once they exceed the TTFT
    step budget: they leave the queue with empty outputs, the lifecycle
    stream records each shed with its wait, and the surviving request
    still matches the reference."""
    cfg, params = dense_model
    trace = Trace(
        prompt_lens=(6, 4, 4), max_news=(12, 4, 4),
        submit_steps=(0, 1, 1), pool_blocks=5, preempt_mode="swap",
        max_slots=1, horizon=1,
        tenants=("batch", "interactive", "interactive"),
        priorities=(0, 2, 2), policy="priority", ttft_budget_steps=3,
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    m = engine.metrics
    shed_rids = sorted(rec["rid"] for rec in m.sheds)
    assert shed_rids == [1, 2], "both blocked interactive requests shed"
    for rec in m.sheds:
        assert rec["tenant"] == "interactive"
        assert rec["wait_steps"] > trace.ttft_budget_steps
        assert engine.results[rec["rid"]] == []
    assert m.counters()["sheds"] == list(m.sheds)
    assert m.summary()["sheds"] == 2
    # nothing was ever admitted for the shed rids: exactly one admission
    assert [a["rid"] for a in m.admissions] == [0]


def test_slo_shed_in_fuzzed_tenant_mix(dense_model):
    """Fuzz leg with a live SLO budget: a tight pool + tiny TTFT budget
    over the tenant mix triggers ≥ 1 shed, and every request either
    shed cleanly (no tokens) or decoded bit-identically to the
    reference — partial results are impossible."""
    cfg, params = dense_model
    base = _tenant_mix_trace(np.random.default_rng(11))
    trace = dataclasses.replace(
        base, pool_blocks=base.min_pool, max_slots=2, horizon=1,
        policy="fcfs", ttft_budget_steps=2,
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    assert len(engine.metrics.sheds) >= 1, (
        "saturated pool + 2-step TTFT budget must shed at least once"
    )


def test_cross_tenant_preemption_for_higher_class(dense_model):
    """Under ``policy="priority"`` pool pressure lands on the lowest
    class first: the interactive request arrives while the batch tenant
    is mid-decode, and when its growth hits a dry pool the batch slot is
    preempted *for* it — visible in the preemption record as
    ``tenant != for_tenant`` — and both requests still finish with
    reference-identical outputs."""
    cfg, params = dense_model
    trace = Trace(
        prompt_lens=(4, 3), max_news=(16, 12), submit_steps=(0, 2),
        pool_blocks=5, preempt_mode="swap", max_slots=2, horizon=1,
        tenants=("batch", "interactive"), priorities=(0, 2),
        policy="priority",
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    cross = [
        p for p in engine.metrics.preemptions
        if p["for_tenant"] and p["tenant"] != p["for_tenant"]
    ]
    assert cross, "expected a cross-tenant preemption under priority"
    assert all(
        p["tenant"] == "batch" and p["for_tenant"] == "interactive"
        for p in cross
    ), "priority policy must never evict the higher class for the lower"


def test_fair_policy_tracks_tenant_tokens(dense_model):
    """``policy="fair"`` (WDRR over decode-token grants) keeps an exact
    per-tenant token ledger: the recorded ``tenant_tokens`` equal each
    tenant's summed finished-output lengths, and the deficit state never
    leaks into outputs (reference-identical, checked above per step)."""
    cfg, params = dense_model
    base = _tenant_mix_trace(np.random.default_rng(23))
    trace = dataclasses.replace(base, policy="fair")
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    want: dict = {}
    for req in trace.requests(cfg.vocab_size):
        want[req.tenant] = (
            want.get(req.tenant, 0) + len(engine.results[req.rid])
        )
    got = engine.metrics.counters()["tenant_tokens"]
    assert got == {t: n for t, n in want.items() if n > 0}


def test_readmission_accounting_under_churn(dense_model):
    """Regression (re-admission accounting): a churny trace counts each
    request's *first* admission exactly once in ``admissions`` — swap-in
    and re-prefill returns land in ``readmissions`` — so queue-depth
    and TTFT summaries are per-request, not per-churn-event. TTFT stays
    anchored at arrival: one sample per request no matter how often it
    was preempted."""
    cfg, params = dense_model
    trace = Trace(
        prompt_lens=(4, 4, 4), max_news=(10, 10, 10),
        submit_steps=(0, 0, 0), pool_blocks=4, preempt_mode="swap",
        max_slots=3, horizon=1,
    )
    engine = run_trace(cfg, params, trace)
    assert_outputs_match_reference(cfg, params, engine, trace)
    m = engine.metrics
    n = len(trace.prompt_lens)
    assert m.summary()["preemptions"] >= 1, "trace must actually churn"
    assert sorted(a["rid"] for a in m.admissions) == list(range(n))
    assert all(not a.get("resumed") for a in m.admissions)
    assert all(r["resumed"] for r in m.readmissions)
    # every preemption of a finishing request is balanced by a re-entry
    assert len(m.readmissions) == len(m.preemptions)
    # TTFT: one sample per request, measured from original arrival
    assert len(m.ttft_s) == n
    assert m.summary()["readmissions"] == len(m.preemptions)


# ================================================== fail-closed serving
# The headline invariant (docs/serving_robustness.md): under ANY fault
# schedule every request either completes **bit-identical** to the
# fault-free run or terminates with a **typed** ServingFault — and the
# pool drains clean either way (zero leaked pages/slots/refcounts,
# asserted by run_trace after every step and at drain).
def assert_bit_exact_or_typed_error(cfg, params, engine, trace):
    mcfg = engine.model_cfg
    shed_rids = {rec["rid"] for rec in engine.metrics.sheds}
    for req in trace.requests(cfg.vocab_size):
        got = engine.results[req.rid]
        ref = reference_tokens(mcfg, params, req.prompt, req.max_new)
        if req.rid in engine.errors:
            exc = engine.errors[req.rid]
            assert isinstance(exc, ServingFault), exc
            # greedy decode is deterministic, so whatever a terminated
            # request did emit must be a prefix of its fault-free tokens
            # — a non-prefix partial result would be silent corruption
            assert got == ref[: len(got)], (
                f"rid={req.rid}: partial output {got} is not a prefix "
                f"of the fault-free tokens {ref}"
            )
            continue
        if req.rid in shed_rids:
            assert got == [], f"rid={req.rid} was shed but emitted tokens"
            continue
        assert got == ref, (
            f"rid={req.rid}: {got} != fault-free reference {ref}"
        )


FUZZ_SITES = ("swap_out", "swap_in", "pool", "logits")


@pytest.mark.parametrize("seed,horizon,preempt_mode", [
    (0, 1, "swap"),
    (1, 4, "recompute"),
    (2, 8, "swap"),
    (3, 4, "swap"),
])
def test_fault_fuzz_bit_exact_or_typed_error(
    dense_model, seed, horizon, preempt_mode
):
    """Seeded fault-schedule fuzz over horizon × preemption mode on a
    minimal pool (maximum churn): swap and pool faults must recover
    bit-identically (checksum → recompute re-prefill; planning-only
    admission pressure), poisoned logits must terminate exactly their
    request with a typed error, and the whole schedule — outputs,
    errors, AND the deterministic counters — replays bit-identically
    from ``plan.replay()``."""
    cfg, params = dense_model
    base = _random_trace(np.random.default_rng(200 + seed))
    trace = dataclasses.replace(
        base, horizon=horizon, preempt_mode=preempt_mode,
        pool_blocks=base.min_pool,
    )
    rids = list(range(len(trace.prompt_lens)))
    plan = FaultPlan.generate(
        400 + seed, n_faults=8, max_step=16, sites=FUZZ_SITES, rids=rids,
    )
    fault_free = run_trace(cfg, params, trace)
    engine = run_trace(cfg, params, trace, faults=plan)
    assert_bit_exact_or_typed_error(cfg, params, engine, trace)
    # swap/pool faults are recoverable: the only typed terminations a
    # schedule over these sites may produce are poisoned requests
    for rid, exc in engine.errors.items():
        assert isinstance(exc, PoisonedRequest), (rid, exc)
    for rid, toks in fault_free.results.items():
        if rid not in engine.errors:
            assert engine.results[rid] == toks
    ctr = engine.metrics.counters()
    assert ctr["fault_injected"] == plan.injected
    # replay: same schedule ⇒ bit-identical outcomes and counters
    replay_plan = plan.replay()
    engine2 = run_trace(cfg, params, trace, faults=replay_plan)
    assert engine2.results == engine.results
    assert {r: type(e) for r, e in engine2.errors.items()} == \
        {r: type(e) for r, e in engine.errors.items()}
    assert engine2.metrics.counters() == ctr
    assert replay_plan.log == plan.log


if HAS_HYPOTHESIS:
    @given(trace=traces(), fault_seed=st.integers(0, 2**16))
    @settings()  # example counts/deadline come from the conftest profiles
    def test_property_faults_bit_exact_or_typed(dense_model, trace, fault_seed):
        """Hypothesis: ANY trace × ANY transient fault schedule over the
        dense-engine sites drains clean with every request bit-exact or
        typed-errored."""
        cfg, params = dense_model
        plan = FaultPlan.generate(
            fault_seed, n_faults=6, max_step=12, sites=FUZZ_SITES,
            rids=list(range(len(trace.prompt_lens))),
        )
        engine = run_trace(cfg, params, trace, faults=plan)
        assert_bit_exact_or_typed_error(cfg, params, engine, trace)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_faults_bit_exact_or_typed():
        pass


# ------------------------------------------- expert-upload fault plane
@pytest.fixture(scope="module")
def compressed_moe_model(moe_model):
    """The sim MoE model PMQ-compressed into the serving layout with a
    {2, 3}-bit ladder (no 1-bit floor: every bucket has a rung below)."""
    from test_offload import compress_for_serving

    cfg, params = moe_model
    return cfg, compress_for_serving(cfg, params, bits=[2, 2, 3, 3])


def _offload_trace(seed: int, horizon: int) -> Trace:
    rng = np.random.default_rng(seed)
    n = 4
    t = Trace(
        prompt_lens=tuple(int(x) for x in rng.integers(2, 6, n)),
        max_news=tuple(int(x) for x in rng.integers(3, 7, n)),
        submit_steps=(0,) * n, pool_blocks=0, preempt_mode="swap",
        max_slots=3, horizon=horizon,
    )
    return dataclasses.replace(
        t, pool_blocks=max(t.min_pool, (2 * t.demand) // 3)
    )


@pytest.mark.parametrize("budget,horizon,seed", [
    (2, 1, 0), (2, 4, 1), (3, 4, 0),
])
def test_transient_upload_faults_recover_bit_identical(
    compressed_moe_model, budget, horizon, seed
):
    """Transient upload faults (corrupt payloads caught by the per-row
    CRC and re-fetched; I/O failures within the bounded retry budget)
    across offload budgets: outputs bit-identical to the fault-free
    offloaded run, zero typed errors, zero degraded serves — and the
    retry/fault counters replay bit-identically."""
    cfg, cparams = compressed_moe_model
    trace = _offload_trace(50 + seed, horizon)
    plan = FaultPlan.generate(
        70 + seed, n_faults=6, max_step=10, sites=("upload",), max_count=2,
    )
    free = run_trace(cfg, cparams, trace, resident_experts=budget)
    engine = run_trace(
        cfg, cparams, trace, faults=plan, resident_experts=budget,
    )
    assert plan.injected >= 1, "schedule never fired — fuzz is vacuous"
    assert engine.errors == {}
    assert engine.results == free.results
    ctr = engine.metrics.counters()
    assert ctr["fault_injected"] == plan.injected
    assert ctr["upload_retries"] >= 1
    assert ctr["degraded_serves"] == 0
    engine2 = run_trace(
        cfg, cparams, trace, faults=plan.replay(), resident_experts=budget,
    )
    assert engine2.results == engine.results
    assert engine2.metrics.counters() == ctr


def test_persistent_upload_fail_fails_closed_without_degradation(
    compressed_moe_model
):
    """With degradation off, an expert row whose upload fails past the
    retry budget must fail the engine **closed**: step() raises
    ExpertUploadFailed, every live request terminates with a typed
    error, and the pool is fully released — never a hang, never silent
    garbage."""
    cfg, cparams = compressed_moe_model
    trace = _offload_trace(5, 1)
    plan = FaultPlan([FaultSpec(site="upload", mode="fail", count=-1)])
    engine = make_engine(
        cfg, cparams, trace, faults=plan, resident_experts=2,
    )
    for req in trace.requests(cfg.vocab_size):
        engine.submit(req)
    with pytest.raises(ExpertUploadFailed):
        for _ in range(MAX_TICKS):
            if not engine.step():
                break
    assert engine.errors, "fail-closed must record the typed error per rid"
    assert all(
        isinstance(e, ServingFault) for e in engine.errors.values()
    )
    assert_drained_clean(engine, trace)


def test_degraded_requests_match_pinned_oracle(compressed_moe_model):
    """Precision-ladder degradation: persistently failing the target-bit
    upload of the one non-initially-resident 2-bit expert row (every
    layer) with ``degrade_experts=True`` serves that row's 1-bit-snapped
    copy from first use — and the run is **bit-identical** to an oracle
    engine whose host params carry exactly that degraded row baked in
    (pinned bit assignment, no faults). The degrade lifecycle/counter
    and the routing report's ``served_bits`` column witness it."""
    cfg, cparams = compressed_moe_model
    ce = cparams["blocks"]["moe_ce"]
    # resident_experts=3 over counts [2, 2] splits to [1, 2]: bucket b0
    # (2-bit) seeds local slot 0 only, so global slot 1 is the single
    # never-initially-resident row — its first serve must go through the
    # upload path the persistent fault kills (the non-empty ``degraded``
    # map below witnesses exactly that; final budgets may differ because
    # demand overflow grows bucket buffers mid-trace)
    target_gslot = 1
    bucket_i, local = next(
        (i, target_gslot - m.start) for i, m in enumerate(ce.meta)
        if m.start <= target_gslot < m.start + m.count
    )
    from_bits = ce.meta[bucket_i].bits
    num_layers = cfg.num_layers
    plan = FaultPlan([
        FaultSpec(site="upload", mode="fail", key=(l, target_gslot),
                  count=-1)
        for l in range(num_layers)
    ])
    trace = _offload_trace(9, 4)
    engine = run_trace(
        cfg, cparams, trace, faults=plan,
        resident_experts=3, degrade_experts=True, trace_level="full",
    )
    assert engine.errors == {}
    off = engine.offload
    assert off.degraded, "the targeted row was never routed to"
    assert set(off.degraded) <= {
        (l, target_gslot) for l in range(num_layers)
    }
    assert all(v == (from_bits, 1) for v in off.degraded.values())
    assert engine.metrics.counters()["degraded_serves"] >= 1
    rep = engine.routing_report()
    deg = {(d["layer"], d["slot"]) for d in rep["degraded_experts"]}
    assert deg == set(off.degraded)
    for layer_rep in rep["layers"]:
        for e in layer_rep["entries"]:
            want = 1 if (layer_rep["layer"], e["slot"]) in deg else e["bits"]
            assert e["served_bits"] == want

    # oracle: same engine/budget, no faults, the degraded row baked into
    # the host params — the faulted run must reproduce it bit-for-bit
    from repro.serving.offload import degrade_expert_row

    bk = f"b{bucket_i}"
    arrays = {
        k: jax.tree.map(lambda a: np.array(a, copy=True), v)
        for k, v in ce.arrays.items()
    }
    for l in range(num_layers):
        row = jax.tree.map(lambda a: a[l, local], arrays[bk])
        drow = degrade_expert_row(row, from_bits, 1)
        flat_a = jax.tree_util.tree_leaves(arrays[bk])
        flat_d = jax.tree_util.tree_leaves(drow)
        for a, d in zip(flat_a, flat_d):
            a[l, local] = d
    oracle_params = dict(
        cparams,
        blocks=dict(
            cparams["blocks"], moe_ce=dataclasses.replace(ce, arrays=arrays)
        ),
    )
    oracle = run_trace(cfg, oracle_params, trace, resident_experts=3)
    assert engine.results == oracle.results


# --------------------------------------------- cancellation × COW pages
def run_trace_with_cancels(cfg, params, trace: Trace, cancel_at,
                           midprefill=(), **ecfg_kw):
    """The run_trace loop plus client cancellations: ``cancel_at`` maps
    rid → tick (boundary cancel); rids in ``midprefill`` are cancelled
    from a tracer hook right after their *first prefill chunk* completes
    — i.e. genuinely mid-prefill, with KV already written into pages
    that may be COW-shared with the prefix cache."""
    engine = make_engine(cfg, params, trace, **ecfg_kw)
    orig_complete = engine.tracer.complete
    mid = set(midprefill)

    def complete(name, **kw):
        orig_complete(name, **kw)
        args = kw.get("args") or {}
        if name == "prefill_chunk" and args.get("rid") in mid:
            mid.discard(args["rid"])
            assert engine.cancel(args["rid"])

    engine.tracer.complete = complete
    pending = sorted(
        zip(trace.submit_steps, trace.requests(cfg.vocab_size)),
        key=lambda t: t[0],
    )
    tick = 0
    while pending or engine.scheduler.has_work():
        assert tick < MAX_TICKS, "trace failed to drain (livelock?)"
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        for rid, t in cancel_at.items():
            if t == tick:
                engine.cancel(rid)
        if engine.scheduler.has_work():
            engine.step()
            check_invariants(engine)
        tick += 1
    assert_drained_clean(engine, trace)
    return engine


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_cancellation_fuzz_with_prefix_cow(dense_model, seed):
    """Satellite: fuzz cancellation against the COW prefix cache. A
    shared-template trace under pool pressure gets one request cancelled
    mid-prefill (between chunks, template pages COW-shared) and others
    at random megastep boundaries (mid-decode). Every cancelled-live rid
    terminates with RequestCancelled and a prefix-of-reference partial
    output; every survivor decodes bit-identically; refcounts conserve
    (checked after every step) and the pool drains to zero."""
    cfg, params = dense_model
    rng = np.random.default_rng(300 + seed)
    n = 6
    base = Trace(
        prompt_lens=tuple(int(x) for x in rng.integers(1, 5, n)),
        max_news=tuple(int(x) for x in rng.integers(3, 9, n)),
        submit_steps=tuple(sorted(int(x) for x in rng.integers(0, 4, n))),
        pool_blocks=0, preempt_mode=str(rng.choice(["swap", "recompute"])),
        max_slots=4, horizon=int(rng.choice([1, 4])),
        template_len=8, n_templates=2, prefix_cache=True,
    )
    trace = dataclasses.replace(
        base, pool_blocks=max(base.min_pool, (2 * base.demand) // 3)
    )
    # mid-prefill victim = rid 0: first admitted, so it prefills the
    # template cold (8 + suffix ≥ 9 tokens ≥ 3 chunks) and the hook
    # cancels it *between* chunks deterministically. Two more boundary
    # victims at random early ticks — those may already have finished,
    # which must be a clean no-op.
    victims = [0] + [int(x) for x in rng.choice(
        np.arange(1, n), size=2, replace=False
    )]
    midprefill = (victims[0],)
    cancel_at = {victims[1]: int(rng.integers(1, 4)),
                 victims[2]: int(rng.integers(1, 6))}
    engine = run_trace_with_cancels(
        cfg, params, trace, cancel_at, midprefill=midprefill,
    )
    assert_bit_exact_or_typed_error(cfg, params, engine, trace)
    assert all(
        isinstance(e, RequestCancelled) for e in engine.errors.values()
    )
    # the mid-prefill victim was live by construction; its tokens never
    # got as far as a first emit
    assert victims[0] in engine.errors
    assert engine.results[victims[0]] == []
    assert engine.metrics.counters()["cancelled"] == len(engine.errors)
    # cancelling a drained/unknown rid is a clean no-op
    assert engine.cancel(victims[0]) is False
    assert engine.cancel(10_000) is False


# ------------------------------------------------- deadlines + validation
def test_deadline_queued_and_active_terminate_typed(dense_model):
    """``deadline_steps`` is enforced at megastep boundaries for queued
    *and* running requests: a request stuck behind a single-slot hog
    expires with zero tokens; a running request whose decode outlives
    its deadline keeps a prefix-of-reference partial output. Both
    terminate with DeadlineExceeded and release everything."""
    cfg, params = dense_model
    trace = Trace(
        prompt_lens=(6, 4), max_news=(12, 8), submit_steps=(0, 0),
        pool_blocks=8, preempt_mode="swap", max_slots=1, horizon=1,
    )
    reqs = trace.requests(cfg.vocab_size)
    reqs[1] = dataclasses.replace(reqs[1], deadline_steps=3)
    engine = make_engine(cfg, params, trace)
    for r in reqs:
        engine.submit(r)
    ticks = 0
    while engine.scheduler.has_work():
        assert ticks < MAX_TICKS
        engine.step()
        check_invariants(engine)
        ticks += 1
    assert_drained_clean(engine, trace)
    assert isinstance(engine.errors[1], DeadlineExceeded)
    assert engine.results[1] == []  # expired before ever being admitted
    ref0 = reference_tokens(engine.model_cfg, params, reqs[0].prompt, 12)
    assert engine.results[0] == ref0
    assert engine.metrics.counters()["deadline_exceeded"] == 1

    # now the active-request flavor: generous pool, tight deadline
    trace2 = Trace(
        prompt_lens=(4,), max_news=(10,), submit_steps=(0,),
        pool_blocks=8, preempt_mode="swap", max_slots=1, horizon=1,
    )
    req = dataclasses.replace(
        trace2.requests(cfg.vocab_size)[0], deadline_steps=4
    )
    engine2 = make_engine(cfg, params, trace2)
    engine2.submit(req)
    while engine2.scheduler.has_work():
        engine2.step()
        check_invariants(engine2)
    assert_drained_clean(engine2, trace2)
    assert isinstance(engine2.errors[0], DeadlineExceeded)
    got = engine2.results[0]
    ref = reference_tokens(engine2.model_cfg, params, req.prompt, 10)
    assert 0 < len(got) < 10, "mid-decode expiry must keep a partial prefix"
    assert got == ref[: len(got)]


def test_submit_validation_typed_errors(dense_model):
    """Scheduler.submit rejects malformed requests with InvalidRequest —
    which is both a ServingFault and a ValueError (back-compat) — and a
    rejected submit leaves the engine fully serviceable."""
    cfg, params = dense_model
    trace = Trace((4,), (4,), (0,), 8, "swap")
    engine = make_engine(cfg, params, trace)
    good = trace.requests(cfg.vocab_size)[0]
    bad = [
        Request(rid=10, prompt=np.zeros(0, np.int32), max_new=4),
        Request(rid=11, prompt=good.prompt, max_new=0),
        Request(rid=12, prompt=good.prompt, max_new=4, priority=-1),
        Request(rid=13, prompt=good.prompt, max_new=4, deadline_steps=0),
    ]
    for r in bad:
        with pytest.raises(InvalidRequest) as ei:
            engine.submit(r)
        assert isinstance(ei.value, ServingFault)
        assert isinstance(ei.value, ValueError)
        assert ei.value.rid == r.rid
    engine.submit(good)
    # a duplicate of a *live* rid is rejected; the original is untouched
    with pytest.raises(InvalidRequest):
        engine.submit(Request(rid=good.rid, prompt=good.prompt, max_new=4))
    while engine.scheduler.has_work():
        engine.step()
    assert engine.results[good.rid] == reference_tokens(
        engine.model_cfg, params, good.prompt, good.max_new
    )
    assert_drained_clean(engine, trace)


# ------------------------------------------------- watchdog + livelock
def test_watchdog_fails_closed_on_slow_megastep(dense_model):
    """A megastep slower than ``watchdog_timeout_s`` (driven through the
    engine's injectable clock — no sleeping) raises WatchdogTimeout and
    fails closed: typed errors for every live rid, pool fully clean."""
    cfg, params = dense_model
    trace = Trace((4, 3), (8, 6), (0, 0), 8, "swap", max_slots=2)
    engine = make_engine(cfg, params, trace, watchdog_timeout_s=10.0)
    t = [0.0]

    def fake_clock():
        t[0] += 100.0  # every megastep "takes" 100s > the 10s budget
        return t[0]

    engine._clock = fake_clock
    for r in trace.requests(cfg.vocab_size):
        engine.submit(r)
    with pytest.raises(WatchdogTimeout):
        while engine.scheduler.has_work():
            engine.step()
    assert set(engine.errors) == {0, 1}
    assert all(isinstance(e, ServingFault) for e in engine.errors.values())
    assert_drained_clean(engine, trace)


def test_livelock_guard_fails_closed(dense_model):
    """An engine with work that stops making progress (megasteps advance
    nothing) must fail closed with LivelockDetected after
    ``livelock_steps`` boundaries instead of spinning forever."""
    cfg, params = dense_model
    trace = Trace((4,), (8,), (0,), 8, "swap", max_slots=1)
    engine = make_engine(cfg, params, trace, livelock_steps=5)
    engine.submit(trace.requests(cfg.vocab_size)[0])
    engine.step()  # admits + prefills; then the decode path stalls
    engine._decode_megastep = lambda: None
    with pytest.raises(LivelockDetected):
        for _ in range(20):
            engine.step()
    assert isinstance(engine.errors[0], ServingFault)
    assert_drained_clean(engine, trace)


# ------------------------------------------- async expert streaming
@pytest.mark.parametrize("budget,horizon,seed", [
    (2, 1, 0), (2, 4, 1), (3, 4, 0), (3, 2, 2),
])
def test_async_overlap_bit_identical_fuzz(
    compressed_moe_model, budget, horizon, seed
):
    """Double-buffered residency (async_offload=True) is invisible to
    outputs: across budgets × horizons × preemption-pressure traces the
    tokens are bit-identical to the synchronous engine, and the async
    engine's logical counters replay bit-identically (placement
    independence makes the one-boundary-stale plan harmless — misses
    keep the synchronous ensure-resident backstop)."""
    cfg, cparams = compressed_moe_model
    trace = _offload_trace(90 + seed, horizon)
    sync = run_trace(cfg, cparams, trace, resident_experts=budget)
    eng = run_trace(
        cfg, cparams, trace, resident_experts=budget, async_offload=True,
    )
    assert eng.errors == {}
    assert eng.results == sync.results
    ctr = eng.metrics.counters()
    eng2 = run_trace(
        cfg, cparams, trace, resident_experts=budget, async_offload=True,
    )
    assert eng2.results == eng.results
    assert eng2.metrics.counters() == ctr


@pytest.mark.parametrize("seed", [0, 2])
def test_async_overlap_composes_with_upload_faults(
    compressed_moe_model, seed
):
    """In-flight transfers × preemption × fault plans: with
    async_offload=True an injected upload-fault schedule (fired at issue
    time — in-flight failure joins the PR-9 recovery ladder as a
    prefetch failure with deterministic backoff) still serves every
    request bit-identical to the fault-free synchronous run, with no
    degradation and replay-identical counters."""
    cfg, cparams = compressed_moe_model
    trace = _offload_trace(50 + seed, 4)  # pool at 2/3 demand: preempts
    plan = FaultPlan.generate(
        130 + seed, n_faults=6, max_step=10, sites=("upload",), max_count=2,
    )
    free = run_trace(cfg, cparams, trace, resident_experts=2)
    eng = run_trace(
        cfg, cparams, trace, faults=plan, resident_experts=2,
        async_offload=True,
    )
    assert plan.injected >= 1, "schedule never fired — fuzz is vacuous"
    assert eng.errors == {}
    assert eng.results == free.results
    ctr = eng.metrics.counters()
    assert ctr["fault_injected"] == plan.injected
    assert ctr["degraded_serves"] == 0
    eng2 = run_trace(
        cfg, cparams, trace, faults=plan.replay(), resident_experts=2,
        async_offload=True,
    )
    assert eng2.results == eng.results
    assert eng2.metrics.counters() == ctr


def test_async_tiered_store_composes_with_preemption(
    compressed_moe_model, tmp_path
):
    """The full stack at once: disk-backed tiers + bounded host cache +
    async double-buffering + preemption pressure serve bit-identical to
    the plain synchronous in-memory-host engine."""
    cfg, cparams = compressed_moe_model
    trace = _offload_trace(77, 4)
    sync = run_trace(cfg, cparams, trace, resident_experts=2)
    eng = run_trace(
        cfg, cparams, trace, resident_experts=2, async_offload=True,
        offload_dir=str(tmp_path / "tier"), host_expert_bytes=16384,
    )
    assert eng.errors == {}
    assert eng.results == sync.results
    c = eng.metrics.counters()
    assert c["tier_disk_hits"] >= 1
