"""Tests for PMQ bit allocation (Eq. 7): DP vs MILP vs brute force."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pmq import (
    allocate_block_dp,
    allocate_block_milp,
    allocate_model,
    pmq_costs,
)
from repro.core.significance import RouterStats, importance

BITS = (1, 2, 3)


def brute_force(costs, budget, require_floors=True):
    e = costs.shape[0]
    best, best_cost = None, np.inf
    for combo in itertools.product(range(3), repeat=e):
        bits = [BITS[j] for j in combo]
        if sum(bits) != budget:
            continue
        if require_floors and e >= 2 and (2 not in bits or 3 not in bits):
            continue
        c = sum(costs[i, j] for i, j in enumerate(combo))
        if c < best_cost:
            best, best_cost = np.array(bits), c
    return best, best_cost


def _cost_of(costs, bits):
    return sum(costs[i, BITS.index(int(b))] for i, b in enumerate(bits))


@given(
    e=st.integers(2, 7),
    seed=st.integers(0, 10_000),
    avg_times_4=st.integers(6, 11),  # avg bits in [1.5, 2.75]
)
@settings(max_examples=30, deadline=None)
def test_dp_matches_bruteforce(e, seed, avg_times_4):
    rng = np.random.default_rng(seed)
    costs = rng.uniform(0.01, 1.0, size=(e, 3))
    costs = np.sort(costs, axis=1)[:, ::-1].copy()  # lower bits cost more
    budget = max(min(int(round(e * avg_times_4 / 4.0)), 3 * e - 1), e + 3)
    bf_bits, bf_cost = brute_force(costs, budget)
    if bf_bits is None:
        with pytest.raises(ValueError):
            allocate_block_dp(costs, budget)
        return
    dp_bits = allocate_block_dp(costs, budget)
    assert int(dp_bits.sum()) == budget
    assert 2 in dp_bits and 3 in dp_bits
    np.testing.assert_allclose(_cost_of(costs, dp_bits), bf_cost, rtol=1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_dp_matches_milp_large(seed):
    rng = np.random.default_rng(seed)
    e = 64
    costs = np.sort(rng.uniform(0.001, 1.0, size=(e, 3)), axis=1)[:, ::-1].copy()
    budget = int(round(e * 2.05))
    dp_bits = allocate_block_dp(costs, budget)
    milp_bits = allocate_block_milp(costs, budget)
    assert int(dp_bits.sum()) == int(milp_bits.sum()) == budget
    np.testing.assert_allclose(
        _cost_of(costs, dp_bits), _cost_of(costs, milp_bits), rtol=1e-7
    )


def test_dp_384_experts_fast():
    rng = np.random.default_rng(3)
    e = 384  # kimi-k2 scale
    costs = np.sort(rng.uniform(0.001, 1.0, size=(e, 3)), axis=1)[:, ::-1].copy()
    bits = allocate_block_dp(costs, int(e * 2.5))
    assert int(bits.sum()) == int(e * 2.5)


def test_important_experts_get_more_bits():
    e = 8
    eps = np.ones((e, 3)) * [[4.0, 2.0, 1.0]]  # uniform error profile
    phi = np.linspace(0.05, 0.9, e)
    w = np.linspace(0.05, 0.9, e)
    costs = pmq_costs(phi, w, eps)
    bits = allocate_block_dp(costs, budget=16)  # avg 2.0
    # most important expert should get >= bits of least important
    assert bits[-1] >= bits[0]
    assert bits[-1] == 3


def test_allocate_model_hits_global_average():
    rng = np.random.default_rng(4)
    L, E = 5, 8
    phi = rng.uniform(0.01, 1, (L, E))
    w = rng.uniform(0.01, 1, (L, E))
    eps = np.sort(rng.uniform(0.1, 2, (L, E, 3)), axis=2)[:, :, ::-1].copy()
    for target in (1.75, 2.0, 2.25):
        plan = allocate_model(phi, w, eps, target_avg_bits=target)
        np.testing.assert_allclose(plan.avg_bits, target, atol=1.0 / (L * E) + 1e-9)
        for b in plan.bits:
            assert 2 in b and 3 in b


def test_allocate_model_layer_adaptive_total_preserved():
    rng = np.random.default_rng(5)
    L, E = 4, 16
    phi = rng.uniform(0.01, 1, (L, E))
    w = rng.uniform(0.01, 1, (L, E))
    eps = np.sort(rng.uniform(0.1, 2, (L, E, 3)), axis=2)[:, :, ::-1].copy()
    eps[0] *= 10.0  # layer 0 is very sensitive
    plan_u = allocate_model(phi, w, eps, 2.0, layer_adaptive=False)
    plan_a = allocate_model(phi, w, eps, 2.0, layer_adaptive=True)
    assert abs(plan_a.avg_bits - 2.0) < 1e-9
    # sensitive layer got at least as many bits as uniform gave it
    assert plan_a.layer_budgets[0] >= plan_u.layer_budgets[0]


def test_router_stats_accumulate():
    stats = RouterStats(num_experts=4)
    stats.update(np.array([[0, 1], [1, 2]]), np.array([[0.9, 0.1], [0.6, 0.4]]))
    np.testing.assert_allclose(stats.phi, [0.5, 1.0, 0.5, 0.0])
    np.testing.assert_allclose(stats.w, [0.45, 0.35, 0.2, 0.0])
    imp = importance(stats.phi, stats.w, 1.0, 0.5)
    assert imp[1] > imp[0] > imp[3]
