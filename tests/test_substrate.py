"""Distributed-substrate tests: checkpointing, fault tolerance, elastic,
data pipeline determinism, optimizer, gradient compression."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import HostDataLoader, SyntheticLM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.grad_compress import dequantize_grad, ef_compress, quantize_grad
from repro.runtime.fault_tolerance import (
    FailurePolicy,
    HeartbeatTable,
    ResilientLoop,
    StragglerMonitor,
)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    ckpt.save(5, tree, blocking=True)
    out = ckpt.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(8.0))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.ones((3, 3)))


def test_checkpoint_keep_last_k_and_latest(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2, async_write=False)
    tree = {"x": jnp.zeros(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(s, {"x": jnp.full(4, float(s))})
    assert ckpt.all_steps() == [3, 4]
    assert ckpt.latest_step() == 4
    out = ckpt.restore(4, tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.full(4, 4.0))


def test_checkpoint_async_writer(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=3, async_write=True)
    for s in range(3):
        ckpt.save(s, {"x": jnp.full(2, float(s))})
    ckpt.wait()
    assert ckpt.all_steps() == [0, 1, 2]


def test_checkpoint_crash_safety_tmp_invisible(tmp_path):
    # a .tmp dir without manifest must be invisible
    os.makedirs(tmp_path / "step_00000007.tmp")
    ckpt = Checkpointer(str(tmp_path), async_write=False)
    assert ckpt.latest_step() is None


# --------------------------------------------------------- fault tolerance
def test_heartbeat_failure_detection():
    hb = HeartbeatTable([0, 1, 2], timeout=10.0)
    now = time.monotonic()
    hb.beat(0, now)
    hb.beat(1, now - 20)  # stale
    hb.beat(2, now)
    assert hb.failed(now) == [1]
    assert hb.alive(now) == [0, 2]


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(window=8, threshold=1.5)
    for step in range(8):
        for host in range(4):
            mon.record(host, 1.0 if host != 2 else 2.5)
    assert mon.stragglers() == [2]


def test_resilient_loop_restores_and_shrinks():
    calls = {"restore": 0, "shrink": 0}
    fails_at = {3, 4}

    def step(i):
        if i in fails_at:
            fails_at.remove(i)
            raise RuntimeError("node died")
        return {"step": i}

    loop = ResilientLoop(
        FailurePolicy(
            max_restarts=3,
            restore_fn=lambda: calls.__setitem__("restore", calls["restore"] + 1),
            shrink_fn=lambda: calls.__setitem__("shrink", calls["shrink"] + 1),
            shrink_after=2,
        )
    )
    out = loop.run(step, start=0, steps=8)
    assert out == {"step": 7}
    assert calls["restore"] == 2
    assert calls["shrink"] == 1  # second failure triggered the shrink path


def test_resilient_loop_gives_up():
    loop = ResilientLoop(FailurePolicy(max_restarts=1))

    def bad(i):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        loop.run(bad, 0, 3)


# ------------------------------------------------------------------ data
def test_data_deterministic_per_step_and_host():
    l0 = HostDataLoader(vocab=100, global_batch=8, seq_len=16, host_id=0,
                        num_hosts=2)
    l0b = HostDataLoader(vocab=100, global_batch=8, seq_len=16, host_id=0,
                         num_hosts=2)
    l1 = HostDataLoader(vocab=100, global_batch=8, seq_len=16, host_id=1,
                        num_hosts=2)
    a = l0.batch_at(7)
    b = l0b.batch_at(7)
    c = l1.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # restart-safe
    assert not np.array_equal(a["tokens"], c["tokens"])  # host shards differ
    assert a["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_synthetic_corpus_is_learnable_structure():
    corpus = SyntheticLM(vocab=64, seed=0)
    rng = np.random.default_rng(0)
    seq = corpus.sample(rng, 64, 64)
    # bigram entropy must be far below uniform (structure to learn)
    from collections import Counter

    pairs = Counter(zip(seq[:, :-1].reshape(-1), seq[:, 1:].reshape(-1)))
    uni = Counter(seq.reshape(-1))
    n = sum(pairs.values())
    h2 = -sum(c / n * np.log2(c / n) for c in pairs.values())
    h1 = -sum(c / seq.size * np.log2(c / seq.size) for c in uni.values())
    cond = h2 - h1  # H(next | prev)
    assert cond < 0.8 * np.log2(64), (cond, np.log2(64))


# -------------------------------------------------------------- optimizer
def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(16,)), jnp.float32)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros(16)}, loss, target


@pytest.mark.parametrize("state_bits", [32, 8])
def test_adamw_converges(state_bits):
    params, loss, target = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_bits=state_bits)
    opt = adamw_init(params, cfg)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(params, g, opt, cfg)
    assert float(loss(params)) < 0.05


def test_adamw_master_copy_bf16():
    params = {"w": jnp.zeros(8, jnp.bfloat16)}
    cfg = AdamWConfig(lr=0.01, master=True, weight_decay=0.0)
    opt = adamw_init(params, cfg)
    assert opt["per_param"]["w"]["master"].dtype == jnp.float32
    g = {"w": jnp.ones(8, jnp.bfloat16)}
    p2, opt2 = adamw_update(params, g, opt, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    # master moved even where bf16 rounding would hide it
    assert float(jnp.abs(opt2["per_param"]["w"]["master"]).sum()) > 0


# ------------------------------------------------------- grad compression
def test_grad_quantize_roundtrip_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(1000,)) * 3.0, jnp.float32)
    q, s = quantize_grad(g)
    deq = dequantize_grad(q, s, g.shape)
    # error bounded by half a step per block
    step = np.repeat(np.asarray(s), 256)[:1000]
    assert np.all(np.abs(np.asarray(g - deq)) <= step * 0.51 + 1e-7)


def test_error_feedback_unbiased_over_time():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
    residual = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        _, deq, residual = ef_compress(g, residual)
        acc = acc + deq
    # with EF, the mean transmitted gradient converges to g
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g), atol=0.02)
