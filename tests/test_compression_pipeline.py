"""End-to-end MC# pipeline tests on a small MoE model.

Covers: calibration capture, eps computation, PMQ allocation, GPTQ
compression, compressed forward fidelity (vs fp), OTP training
integration, and the compressed-vs-fp agreement ordering across bit
budgets (higher bits → closer to fp — the Pareto sanity check).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import pipeline
from repro.core.compressed_moe import build_compressed_experts, compressed_moe_layer
from repro.core.otp_train import OTPTrainConfig, train_otp
from repro.models import transformer as tf
from repro.models.moe import moe_layer
from repro.models.registry import get_model

CFG = ModelConfig(
    name="test-moe",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=128,
    d_ff_expert=128,
    vocab_size=256,
    num_experts=8,
    top_k=2,
    num_shared_experts=1,
    dtype="float32",
    remat="none",
    logits_chunk=32,
    attn_q_chunk=32,
    attn_kv_chunk=32,
    moe_capacity_factor=2.0,
)


@pytest.fixture(scope="module")
def model_and_calib():
    bundle = get_model(CFG)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab_size, (4, 64)), jnp.int32)
    calib = pipeline.calibrate(params, tokens, CFG)
    return bundle, params, tokens, calib


def test_calibration_stats(model_and_calib):
    _, params, tokens, calib = model_and_calib
    assert calib.phi.shape == (2, 8)
    assert calib.w.shape == (2, 8)
    # frequencies: each token activates top_k experts
    np.testing.assert_allclose(calib.phi.sum(axis=1), CFG.top_k, rtol=1e-6)
    assert (calib.w >= 0).all()
    assert len(calib.moe_inputs) == 2


def test_eps_monotone_in_bits(model_and_calib):
    _, params, tokens, calib = model_and_calib
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=128)
    assert eps.shape == (2, 8, 3)
    # more bits → lower reconstruction error, per expert (weak: on average)
    assert (eps[..., 0] >= eps[..., 2]).mean() > 0.9


def test_pmq_plan_and_compress(model_and_calib):
    _, params, tokens, calib = model_and_calib
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=128)
    plan = pipeline.run_pmq(params, calib, CFG, target_avg_bits=2.0, eps=eps)
    assert abs(plan.avg_bits - 2.0) < 1e-9
    blocks_c, top = pipeline.compress_model(
        params, calib, plan, CFG, use_gptq=True, gptq_tokens=256
    )
    # compressed weights much smaller than fp32 expert weights
    fp_bytes = sum(
        np.asarray(v).nbytes
        for v in jax.tree.leaves(params["blocks"])
    )
    c_bytes = pipeline.model_weight_bytes(blocks_c, top)
    assert c_bytes < fp_bytes
    # hidden-state fidelity vs fp model (random-init weights: cosine is the
    # right scale-free metric; argmax agreement only makes sense on trained
    # models and is measured in benchmarks/)
    logits_c, _ = pipeline.compressed_logits(blocks_c, top, tokens[:2], CFG)
    h_c, _ = pipeline.compressed_forward(blocks_c, top, tokens[:2], CFG)
    hidden, _, _ = tf.forward_hidden(params, tokens[:2], CFG)
    a = np.asarray(h_c, np.float64).reshape(-1)
    b = np.asarray(hidden, np.float64).reshape(-1)
    cos = a @ b / (np.linalg.norm(a) * np.linalg.norm(b))
    assert cos > 0.6, f"2-bit hidden cosine too low: {cos}"
    assert np.isfinite(np.asarray(logits_c)).all()


def test_gptq_beats_rtn_at_model_level(model_and_calib):
    _, params, tokens, calib = model_and_calib
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=128)
    plan = pipeline.run_pmq(params, calib, CFG, target_avg_bits=2.0, eps=eps)
    hidden_fp, _, _ = tf.forward_hidden(params, tokens[:2], CFG)
    errs = {}
    for use_gptq in (False, True):
        blocks_c, top = pipeline.compress_model(
            params, calib, plan, CFG, use_gptq=use_gptq, gptq_tokens=256
        )
        h_c, _ = pipeline.compressed_forward(blocks_c, top, tokens[:2], CFG)
        errs[use_gptq] = float(jnp.mean((h_c - hidden_fp) ** 2))
    assert errs[True] < errs[False] * 1.05, errs


def test_higher_budget_closer_to_fp(model_and_calib):
    """Pareto sanity: avg 2.5 bits beats avg 1.6 bits in output MSE."""
    _, params, tokens, calib = model_and_calib
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=128)
    hidden_fp, _, _ = tf.forward_hidden(params, tokens[:2], CFG)
    mses = []
    for target in (1.6, 2.5):
        plan = pipeline.run_pmq(params, calib, CFG, target_avg_bits=target, eps=eps)
        blocks_c, top = pipeline.compress_model(
            params, calib, plan, CFG, use_gptq=False
        )
        h_c, _ = pipeline.compressed_forward(blocks_c, top, tokens[:2], CFG)
        mses.append(float(jnp.mean((h_c - hidden_fp) ** 2)))
    assert mses[1] < mses[0], mses


def test_compressed_moe_layer_matches_dequant_reference():
    """Bucketed compressed layer == moe_layer on fake-quantized weights."""
    rng = jax.random.PRNGKey(5)
    bundle = get_model(CFG)
    params = bundle.init(rng)
    p_l = tf.unstack_blocks(params, CFG)[0]
    x = jax.random.normal(rng, (2, 16, CFG.d_model))
    bits = np.array([1, 1, 2, 2, 2, 3, 3, 2])
    experts = {k: np.asarray(p_l["moe"]["experts"][k]) for k in
               ("w_gate", "w_up", "w_down")}
    ce = build_compressed_experts(experts, bits, group=128, ep=1, refine=False)
    y_c, info = compressed_moe_layer(p_l["moe"], ce, x, CFG)
    # reference: fake-quantize each expert at its width, run normal layer
    from repro.core.quantizers import quantize_to_packed

    fq = {k: [] for k in experts}
    for i in range(8):
        for k in experts:
            pt = quantize_to_packed(jnp.asarray(experts[k][i]), int(bits[i]),
                                    group=128, refine=False)
            fq[k].append(pt.dequantize())
    p_ref = dict(p_l["moe"], experts={k: jnp.stack(v) for k, v in fq.items()})
    out_ref = moe_layer(p_ref, x, CFG)
    np.testing.assert_allclose(
        np.asarray(y_c), np.asarray(out_ref.y), rtol=5e-4, atol=5e-4
    )


def test_otp_training_increases_mask_ratio_and_keeps_kl_low(model_and_calib):
    _, params, tokens, calib = model_and_calib
    eps = pipeline.compute_eps(params, calib, CFG, eps_tokens=128)
    plan = pipeline.run_pmq(params, calib, CFG, target_avg_bits=2.0, eps=eps)
    blocks_c, top = pipeline.compress_model(params, calib, plan, CFG, use_gptq=False)
    rng = np.random.default_rng(1)
    data = rng.integers(0, CFG.vocab_size, (32, 32)).astype(np.int32)
    tcfg = OTPTrainConfig(steps=30, batch=4, lr=5e-3, lam=2.0, seed=0)
    otp_params, hist = train_otp(blocks_c, top, CFG, data, tcfg)
    r_first = np.mean([h["mask_ratio"] for h in hist[:5]])
    r_last = np.mean([h["mask_ratio"] for h in hist[-5:]])
    assert r_last > r_first, (r_first, r_last)  # sparsity pressure works
    assert np.isfinite(hist[-1]["kl"])
